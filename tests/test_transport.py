"""Integrity layer, transport half (DESIGN.md §14): sequence-numbered,
CRC32-checksummed envelopes with at-least-once retransmission and
receiver-side dedup carry every nomadic item.  The headline property:
under ANY seeded link-fault script (drop / duplicate / reorder /
corrupt / delay), no item is ever lost or double-applied and the
execution stays bitwise exactly-serializable; with faults off, the
envelope path is bitwise-identical to the plain simulator.
"""
import numpy as np
import pytest
import strategies
from hypothesis_compat import given, settings, st

from repro.core import objective, serial
from repro.core.async_sim import NomadSimulator, SimConfig
from repro.core.stepsize import PowerSchedule
from repro.runtime.chaos import DegradedLink, LinkEvent, seeded_link_script
from repro.runtime.transport import (Envelope, ItemLedger, TransportConfig,
                                     decode_item, encode_item, seal)


# --------------------------------------------------------------------- #
# Envelope / ledger units                                                #
# --------------------------------------------------------------------- #

def test_envelope_roundtrip_and_crc():
    env = seal(src=1, dst=2, seq=7, payload=encode_item(42, 3))
    assert env.verify()
    assert decode_item(env.payload) == (42, 3)
    # any single bit flip in the payload is caught
    for bit in range(8 * len(env.payload)):
        assert not env.corrupted(bit).verify(), f"bit {bit} undetected"


def test_envelope_corrupted_is_pure():
    env = seal(src=0, dst=1, seq=0, payload=encode_item(5, 0))
    bad = env.corrupted(3)
    assert env.verify() and not bad.verify()
    assert bad.seq == env.seq and bad.crc == env.crc


def test_retry_delay_backoff():
    t = TransportConfig(backoff=2.0, max_retries=4)
    base = 10.0
    delays = [t.retry_delay(base, a) for a in (1, 2, 3)]
    assert delays == [10.0, 20.0, 40.0]


def test_transport_config_validation():
    with pytest.raises(ValueError):
        TransportConfig(max_retries=0)
    with pytest.raises(ValueError):
        TransportConfig(backoff=0.5)
    with pytest.raises(ValueError):
        TransportConfig(timeout=-1.0)


def test_ledger_exactly_once():
    led = ItemLedger(3)
    v1 = led.launch(1)
    assert led.accept(1, v1)            # first copy applies
    assert not led.accept(1, v1)        # duplicate discarded
    v2 = led.launch(1)                  # item re-circulates
    assert not led.accept(1, v1)        # stale old-version copy
    assert led.accept(1, v2)
    s = led.stats.as_dict()
    assert s["sent"] == 2 and s["delivered"] == 2
    assert s["duplicates"] == 1 and s["stale"] == 1


# --------------------------------------------------------------------- #
# Simulator integration                                                  #
# --------------------------------------------------------------------- #

def _sim(cfg, seed=0, m=40, n=20, nnz=300, k=4):
    rows, cols, vals = strategies.coo_problem(seed, m, n, nnz)
    W0, H0 = objective.init_factors_np(seed, m, n, k)
    sim = NomadSimulator(cfg, m, n, rows, cols, vals, W0, H0)
    return sim.run(), (rows, cols, vals, W0, H0)


def _replay(res, rows, cols, vals, W0, H0, sched, lam):
    order_idx = sorted(range(len(res.update_log)),
                       key=lambda t: (res.update_log[t][0], t))
    order = np.array([res.update_log[t][1] for t in order_idx])
    cnt = {}
    lrs = np.empty(len(order))
    for t, g in enumerate(order):
        c = cnt.get(g, 0)
        lrs[t] = sched(c)
        cnt[g] = c + 1
    return serial.replay_np(W0, H0, rows, cols, vals, order, lrs, lam)


_SCHED = PowerSchedule(alpha=0.02, beta=0.1)


def _cfg(**kw):
    kw.setdefault("p", 4)
    kw.setdefault("k", 4)
    kw.setdefault("lam", 0.01)
    kw.setdefault("schedule", _SCHED)
    kw.setdefault("epochs", 2.0)
    kw.setdefault("seed", 0)
    return SimConfig(**kw)


def test_envelope_only_path_is_bitwise_identical():
    """transport= without link faults prices every hop through the same
    envelope seal/verify but must not move a single event: W, H and the
    serializability witness are bitwise those of the plain run."""
    plain, _ = _sim(_cfg())
    sealed, _ = _sim(_cfg(transport=TransportConfig()))
    assert np.array_equal(plain.W, sealed.W)
    assert np.array_equal(plain.H, sealed.H)
    assert plain.update_log == sealed.update_log
    assert sealed.transport is not None
    assert sealed.transport["corrupt"] == 0
    assert sealed.transport["dropped"] == 0
    assert sealed.transport["duplicates"] == 0
    # only items still on the wire at the horizon go undelivered
    assert 0 < sealed.transport["delivered"] <= sealed.transport["sent"]
    assert plain.transport is None


def test_degraded_link_delivers_and_serializes():
    link = DegradedLink(drop=0.15, dup=0.1, reorder=0.1, corrupt=0.1,
                        delay=0.1)
    res, (rows, cols, vals, W0, H0) = _sim(
        _cfg(transport=TransportConfig(), link_faults=link))
    s = res.transport
    assert s["dropped"] > 0 and s["duplicates"] > 0 and s["corrupt"] > 0
    assert s["retransmits"] > 0
    assert res.n_updates > 0
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, _SCHED, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


def test_link_without_transport_config_defaults():
    """link_faults= alone auto-enables the checksummed transport."""
    res, _ = _sim(_cfg(link_faults=DegradedLink(drop=0.2)))
    assert res.transport is not None and res.transport["dropped"] > 0


def test_scripted_blackout_window_recovers():
    """A total drop window on every link: retransmission timers must
    carry every in-flight item across the blackout."""
    link = DegradedLink(events=(LinkEvent("drop", t0=20.0, t1=60.0,
                                          prob=1.0),))
    res, (rows, cols, vals, W0, H0) = _sim(
        _cfg(transport=TransportConfig(), link_faults=link))
    assert res.transport["dropped"] > 0
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, _SCHED, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


@pytest.mark.chaos
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(2, 6))
def test_any_fault_script_stays_serializable(seed, p):
    """The headline property: ANY seeded fault script (scripted windows
    + background rates + a worker failure and a rejoin) still yields an
    exactly-serializable history, bitwise."""
    link = DegradedLink(events=tuple(seeded_link_script(seed, 400.0, p=p)),
                        drop=0.1, dup=0.08, reorder=0.08, corrupt=0.08,
                        delay=0.08)
    cfg = _cfg(p=p, seed=seed, transport=TransportConfig(),
               link_faults=link, failures=((60.0, 0),),
               rejoins=((150.0, 1),))
    res, (rows, cols, vals, W0, H0) = _sim(cfg, seed=seed)
    assert res.n_updates > 0
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, _SCHED, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


# --------------------------------------------------------------------- #
# API surface                                                            #
# --------------------------------------------------------------------- #

def test_solve_exposes_transport_stats():
    from repro import api
    prob = api.MCProblem.synthetic(40, 20, 300, k=4, seed=0)
    cfg = api.AsyncSimConfig(k=4, p=3, epochs=2.0, seed=0,
                             transport=api.TransportConfig(),
                             link_faults=api.DegradedLink(drop=0.1))
    res = api.solve(prob, cfg)
    st_ = res.extras["transport"]
    assert st_["sent"] > 0 and st_["delivered"] > 0
    plain = api.solve(prob, api.AsyncSimConfig(k=4, p=3, epochs=2.0,
                                               seed=0))
    assert "transport" not in plain.extras


def test_asyncsim_config_validates_transport_types():
    from repro import api
    with pytest.raises(TypeError):
        api.AsyncSimConfig(k=4, transport="fast")
    with pytest.raises(TypeError):
        api.AsyncSimConfig(k=4, link_faults={"drop": 0.5})
    with pytest.raises(ValueError):
        api.AsyncSimConfig(k=4, mode="dsgd", link_faults=api.DegradedLink(
            drop=0.1))
