"""Tolerance tier (DESIGN.md §13): bounded — not bitwise — assertions
for the deliberate approximations.

Covered here:

* bf16/fp16 factor storage vs. the fp32 oracle across every engine
  surface (``xla``/``wave``/``wave_pallas`` × ``loop``/``fused``),
  bounded by the ``eps * sqrt(updates)`` drift model in
  :mod:`tolerance`;
* convergence equivalence at the bench shape (512 x 256, k=100) — the
  acceptance gate for the precision policy;
* the low-precision checkpoint round-trip (save bf16 -> restore ->
  resume) staying bitwise *within* the bf16 world;
* int8 serving quantization, bounded by the analytic per-row absmax
  quantization error.

Everything runs on CPU; hypothesis drives extra shapes where installed
(seed-parametrized fallbacks always run, via ``hypothesis_compat``).
Run this file alone with ``-m tolerance``.
"""
import numpy as np
import pytest
import strategies  # noqa: F401  (bundles used via hypothesis)
import tolerance as tol
from hypothesis_compat import given, settings, st

from repro import api

pytestmark = pytest.mark.tolerance

IMPL_DISPATCH = [(i, d)
                 for i in ("xla", "wave", "wave_pallas")
                 for d in ("loop", "fused")]

_M, _N, _NNZ, _K, _EPOCHS = 120, 60, 3000, 8, 3


def _mk_problem(seed=0, m=_M, n=_N, nnz=_NNZ, k=_K):
    from repro.data.synthetic import synthetic_ratings, train_test_split
    rows, cols, vals, _, _ = synthetic_ratings(m, n, nnz, k=k, seed=seed,
                                               noise=0.05)
    train, test = train_test_split(rows, cols, vals, 0.1, seed=seed + 1)
    return api.MCProblem(rows=train[0], cols=train[1], vals=train[2],
                         m=m, n=n, test=test)


def _solve(problem, *, impl, dispatch, dtype_policy, k=_K,
           epochs=_EPOCHS, seed=0):
    return api.solve(problem, api.NomadConfig(
        k=k, p=2, lam=0.05, epochs=epochs, seed=seed, kernel=impl,
        dispatch=dispatch, dtype_policy=dtype_policy))


# one fp32 oracle per engine surface, shared across the policy matrix
_ORACLE = {}


def _fp32(problem, impl, dispatch):
    key = (impl, dispatch)
    if key not in _ORACLE:
        _ORACLE[key] = _solve(problem, impl=impl, dispatch=dispatch,
                              dtype_policy="fp32")
    return _ORACLE[key]


@pytest.fixture(scope="module")
def problem():
    return _mk_problem()


# --------------------------------------------------------------------- #
# low-precision factors vs. the fp32 oracle, full engine matrix          #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("impl,dispatch", IMPL_DISPATCH)
@pytest.mark.parametrize("policy", ["bf16", "fp16"])
def test_lowp_factor_drift_bounded(problem, impl, dispatch, policy):
    oracle = _fp32(problem, impl, dispatch)
    res = _solve(problem, impl=impl, dispatch=dispatch,
                 dtype_policy=policy)
    want = {"bf16": "bfloat16", "fp16": "float16"}[policy]
    assert str(np.asarray(res.W).dtype) == want
    nnz = len(problem.rows)
    tol.assert_factors_close(res.W, oracle.W, dtype_policy=policy,
                             n_updates=_EPOCHS * nnz / _M, what="W")
    tol.assert_factors_close(res.H, oracle.H, dtype_policy=policy,
                             n_updates=_EPOCHS * nnz / _N, what="H")
    tol.assert_convergence_equivalent(res.trace_rmse, oracle.trace_rmse,
                                      rel=0.10)


def test_fp32_policy_is_bitwise_noop(problem):
    """`dtype_policy='fp32'` must not merely be *close* to the historical
    path — it must be byte-for-byte it (the PR's bitwise acceptance)."""
    base = api.solve(problem, api.NomadConfig(
        k=_K, p=2, lam=0.05, epochs=_EPOCHS, seed=0, kernel="xla"))
    res = _solve(problem, impl="xla", dispatch="fused",
                 dtype_policy="fp32")
    tol.assert_bitwise(res.W, base.W, "W")
    tol.assert_bitwise(res.H, base.H, "H")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["bf16", "fp16"]))
def test_lowp_drift_bounded_property(seed, policy):
    """Hypothesis-driven shapes for the drift bound (xla/fused surface —
    the matrix test above covers the impl cross-product)."""
    prob = _mk_problem(seed=seed, m=48, n=24, nnz=600, k=4)
    oracle = api.solve(prob, api.NomadConfig(
        k=4, p=2, lam=0.05, epochs=2, seed=0, kernel="xla"))
    res = api.solve(prob, api.NomadConfig(
        k=4, p=2, lam=0.05, epochs=2, seed=0, kernel="xla",
        dtype_policy=policy))
    nnz = len(prob.rows)
    tol.assert_factors_close(res.W, oracle.W, dtype_policy=policy,
                             n_updates=2 * nnz / 48, what="W")
    tol.assert_factors_close(res.H, oracle.H, dtype_policy=policy,
                             n_updates=2 * nnz / 24, what="H")


# --------------------------------------------------------------------- #
# acceptance gate: convergence equivalence at the bench shape            #
# --------------------------------------------------------------------- #

@pytest.mark.slow
def test_bf16_converges_at_bench_shape():
    prob = _mk_problem(seed=3, m=512, n=256, nnz=8192, k=8)
    fp = api.solve(prob, api.NomadConfig(
        k=100, p=4, lam=0.05, epochs=5, seed=0, kernel="xla"))
    bf = api.solve(prob, api.NomadConfig(
        k=100, p=4, lam=0.05, epochs=5, seed=0, kernel="xla",
        dtype_policy="bf16"))
    tol.assert_convergence_equivalent(bf.trace_rmse, fp.trace_rmse,
                                      rel=0.05)


# --------------------------------------------------------------------- #
# low-precision checkpoint round-trip                                    #
# --------------------------------------------------------------------- #

def test_bf16_checkpoint_roundtrip_resumes_bitwise(problem, tmp_path):
    """bf16 is an approximation of fp32, but the bf16 world itself is
    deterministic: save -> restore must be bitwise, and a restored
    warm start must equal the unbroken run byte for byte."""
    from repro.checkpoint import restore_fit_result, save_fit_result
    cfg = dict(k=_K, p=2, lam=0.05, seed=0, kernel="xla",
               dtype_policy="bf16")
    first = api.solve(problem, api.NomadConfig(epochs=2, **cfg))
    assert str(np.asarray(first.W).dtype) == "bfloat16"
    save_fit_result(str(tmp_path), 2, first)
    restored, step = restore_fit_result(str(tmp_path))
    assert step == 2
    assert str(np.asarray(restored.W).dtype) == "bfloat16"
    tol.assert_bitwise(restored.W, first.W, "restored W")
    tol.assert_bitwise(restored.H, first.H, "restored H")
    resumed = api.solve(problem, api.NomadConfig(epochs=3, **cfg),
                        warm_start=restored)       # 2 + 3 == 5 epochs
    unbroken = api.solve(problem, api.NomadConfig(epochs=5, **cfg))
    tol.assert_bitwise(resumed.W, unbroken.W, "resumed W")
    tol.assert_bitwise(resumed.H, unbroken.H, "resumed H")


# --------------------------------------------------------------------- #
# int8 serving quantization                                              #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_int8_scores_within_analytic_bound(seed):
    """Quantized serving scores vs. exact fp32 scores, bounded by the
    per-row absmax quantization error: with ``e = dequant - exact``
    (|e| <= scale/2 elementwise, no clipping by construction),
    |score err| <= 0.5*s_w*sum|H| + 0.5*s_h*sum|W| + 0.25*k*s_w*s_h."""
    from repro.serve import quantize_int8
    rng = np.random.default_rng(seed)
    U, n, k = 16, 200, 24
    W = rng.normal(size=(U, k)).astype(np.float32) * 3
    H = rng.normal(size=(n, k)).astype(np.float32)
    Wq, sw = quantize_int8(W)
    Hq, sh = quantize_int8(H)
    exact = W.astype(np.float64) @ H.astype(np.float64).T
    approx = ((Wq.astype(np.float64) * sw[:, None].astype(np.float64))
              @ (Hq.astype(np.float64) * sh[:, None].astype(np.float64)).T)
    bound = (0.5 * sw[:, None] * np.abs(H).sum(1)[None, :]
             + 0.5 * sh[None, :] * np.abs(W).sum(1)[:, None]
             + 0.25 * k * sw[:, None] * sh[None, :]).astype(np.float64)
    assert np.all(np.abs(approx - exact) <= bound + 1e-12)
    # dequantizing a quantized row is exact under re-quantization
    Wq2, sw2 = quantize_int8(Wq.astype(np.float32) * sw[:, None])
    np.testing.assert_array_equal(Wq2, Wq)
