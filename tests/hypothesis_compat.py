"""Soft dependency shim for ``hypothesis``.

The property-test suite uses hypothesis heavily, but the tier-1 run must
degrade gracefully where it is not installed (it is pinned in
``requirements-dev.txt`` / the ``dev`` extra).  Import ``given``,
``settings`` and ``st`` from here instead of from ``hypothesis``:

* hypothesis installed -> the real decorators, unchanged behaviour;
* hypothesis missing   -> the decorated test calls
  ``pytest.importorskip("hypothesis")`` at run time and reports as
  SKIPPED, while every non-hypothesis test in the module keeps running
  (a bare ``from hypothesis import ...`` would kill collection of the
  whole module).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction at decoration time."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # deliberately no functools.wraps: the skipper must present a
            # zero-arg signature or pytest hunts the strategy params as
            # fixtures
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
