"""Checkpoint: roundtrip, atomicity, GC, async, resume."""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, restore_fit_result,
                              save_checkpoint, save_fit_result)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
                   "blocks": {"pos0": jnp.asarray(rng.normal(size=(2, 3)),
                                                  jnp.bfloat16)}},
        "opt": {"m": jnp.asarray(rng.normal(size=(4,)), jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    restored, step = restore_checkpoint(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step_ignores_uncommitted(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 3, t)
    # fake a torn write: step dir without COMMITTED
    os.makedirs(tmp_path / "step_00000009")
    (tmp_path / "step_00000009" / "shard_0.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 3
    restored, step = restore_checkpoint(str(tmp_path), t)
    assert step == 3


def test_restore_empty_dir(tmp_path):
    restored, step = restore_checkpoint(str(tmp_path / "nope"), _tree())
    assert restored is None and step is None


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        ck.save(s, _tree(s))
    ck.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [30, 40]
    restored, step = restore_checkpoint(str(tmp_path), _tree())
    assert step == 40


def test_fit_result_roundtrip_and_bitwise_resume(tmp_path, tiny_mc_problem):
    """restore_fit_result + solve(warm_start=...) must equal the
    uninterrupted run bitwise, with the full config — step-size
    schedule, kernel policy, *and* ownership schedule — surviving the
    round-trip."""
    from repro import api
    from repro.core.stepsize import PowerSchedule

    pr = tiny_mc_problem
    rows, cols, vals = pr["train"]
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=pr["m"],
                            n=pr["n"], test=pr["test"])
    cfg = api.NomadConfig(k=pr["k"], p=4, epochs=4, kernel="wave",
                          schedule="random", schedule_seed=5,
                          stepsize=PowerSchedule(alpha=0.05, beta=0.02))
    full = api.solve(problem, cfg)

    half_cfg = dataclasses.replace(cfg, epochs=2)
    half = api.solve(problem, half_cfg)
    save_fit_result(str(tmp_path), 2, half)
    restored, step = restore_fit_result(str(tmp_path))
    assert step == 2
    assert restored.config == half_cfg
    assert restored.epochs_done == half.epochs_done
    np.testing.assert_array_equal(restored.W, half.W)
    np.testing.assert_array_equal(restored.trace_rmse, half.trace_rmse)
    resumed = api.solve(problem, dataclasses.replace(restored.config,
                                                     epochs=2),
                        warm_start=restored)
    np.testing.assert_array_equal(resumed.W, full.W)
    np.testing.assert_array_equal(resumed.H, full.H)


def test_fit_result_roundtrip_dispatch_fields(tmp_path, tiny_mc_problem):
    """The fused-driver config fields (dispatch / fuse_epochs /
    record_every) survive the checkpoint, and a restored loop-dispatch
    run resumes bitwise under the fused driver (block boundaries are
    exact resume points)."""
    from repro import api
    from repro.core.stepsize import PowerSchedule

    pr = tiny_mc_problem
    rows, cols, vals = pr["train"]
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=pr["m"],
                            n=pr["n"], test=pr["test"])
    cfg = api.NomadConfig(k=pr["k"], p=4, epochs=2, kernel="wave",
                          dispatch="loop", fuse_epochs=2, record_every=2,
                          stepsize=PowerSchedule(alpha=0.05, beta=0.02))
    half = api.solve(problem, cfg)
    save_fit_result(str(tmp_path), 0, half)
    restored, _ = restore_fit_result(str(tmp_path))
    assert restored.config == cfg
    assert restored.config.dispatch == "loop"
    assert restored.config.fuse_epochs == 2
    assert restored.config.record_every == 2

    full = api.solve(problem, dataclasses.replace(
        cfg, epochs=4, dispatch="fused", record_every=1))
    resumed = api.solve(problem, dataclasses.replace(
        restored.config, dispatch="fused", record_every=1),
        warm_start=restored)
    np.testing.assert_array_equal(resumed.W, full.W)
    np.testing.assert_array_equal(resumed.H, full.H)


def test_fit_result_roundtrip_emitted_schedule(tmp_path, tiny_mc_problem):
    """A simulator run's replayable extras['schedule'] survives the
    checkpoint (so a restart can still replay the predicted routing)."""
    from repro import api

    pr = tiny_mc_problem
    rows, cols, vals = pr["train"]
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=pr["m"],
                            n=pr["n"])
    sim = api.solve(problem, api.AsyncSimConfig(k=4, p=3, epochs=0.5,
                                                emit_schedule=True))
    save_fit_result(str(tmp_path), 0, sim)
    restored, _ = restore_fit_result(str(tmp_path))
    assert restored.extras["schedule"] == sim.extras["schedule"]
    assert restored.virtual_time == sim.virtual_time
    assert restored.solver == "async_sim"
    assert restored.config == sim.config


def test_restore_fit_result_empty(tmp_path):
    restored, step = restore_fit_result(str(tmp_path / "nope"))
    assert restored is None and step is None


def test_train_resume_is_exact(tmp_path):
    """Train 4 steps straight vs 2 + checkpoint + restore + 2: identical
    parameters (restart-after-failure exactness, with the deterministic
    pipeline replaying from the restored step)."""
    from repro import configs
    from repro.launch.train import make_train_step, init_state
    from repro.optim.adamw import AdamWConfig
    from repro.data.pipeline import TokenPipeline

    cfg = configs.get_smoke_config("musicgen_large")
    opt_cfg = AdamWConfig(lr=1e-3)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=2, embed_input=cfg.embed_input,
                         d_model=cfg.d_model)
    step_fn = jax.jit(make_train_step(cfg, None, opt_cfg))

    def batch(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}

    s_a = init_state(jax.random.key(0), cfg, opt_cfg)
    for i in range(4):
        s_a, _ = step_fn(s_a, batch(i))

    s_b = init_state(jax.random.key(0), cfg, opt_cfg)
    for i in range(2):
        s_b, _ = step_fn(s_b, batch(i))
    save_checkpoint(str(tmp_path), 2, s_b)
    s_c, step = restore_checkpoint(str(tmp_path), s_b)
    assert step == 2
    for i in range(2, 4):
        s_c, _ = step_fn(s_c, batch(i))

    for a, c in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_c["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))


def test_gc_spares_latest_committed_despite_torn_newer(tmp_path):
    """Crash-safety regression: a torn (uncommitted) step dir *newer*
    than every committed one must not push the GC cutoff past the latest
    committed checkpoint — and must itself be left alone, because it may
    be a concurrent write still in flight."""
    from repro.checkpoint import gc_checkpoints
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    os.makedirs(tmp_path / "step_00000009")          # torn: no COMMITTED
    os.makedirs(tmp_path / "step_00000010.tmp")      # mid-write staging
    gc_checkpoints(str(tmp_path), keep=1)
    assert latest_step(str(tmp_path)) == 2
    assert (tmp_path / "step_00000009").exists()
    assert (tmp_path / "step_00000010.tmp").exists()
    assert not (tmp_path / "step_00000001").exists()


def test_gc_removes_stale_torn_below_cutoff(tmp_path):
    """Torn dirs strictly older than the keep window are dead weight
    (the writer that produced them already moved on) and are reclaimed."""
    from repro.checkpoint import gc_checkpoints
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    save_checkpoint(str(tmp_path), 7, t)
    os.makedirs(tmp_path / "step_00000003")
    os.makedirs(tmp_path / "step_00000004.tmp")
    gc_checkpoints(str(tmp_path), keep=1)
    assert not (tmp_path / "step_00000003").exists()
    assert not (tmp_path / "step_00000004.tmp").exists()
    assert latest_step(str(tmp_path)) == 7
    assert not (tmp_path / "step_00000005").exists()


def test_boot_skips_torn_newer_dir(tmp_path, tiny_mc_problem):
    """Serving-boot regression: a server coming up while the trainer is
    mid-checkpoint must boot from the newest *committed* step — torn
    newer dirs (no COMMITTED), .tmp staging, and junk names are all
    skipped, never crashed on."""
    from repro import api
    from repro.serve import FactorStore

    pr = tiny_mc_problem
    rows, cols, vals = pr["train"]
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=pr["m"],
                            n=pr["n"], test=pr["test"])
    res = api.solve(problem, api.NomadConfig(k=pr["k"], p=2, epochs=1))
    save_fit_result(str(tmp_path), 4, res)
    os.makedirs(tmp_path / "step_00000009")          # torn: no COMMITTED
    (tmp_path / "step_00000009" / "shard_0.npz").write_bytes(b"garbage")
    os.makedirs(tmp_path / "step_00000010.tmp")      # mid-write staging
    os.makedirs(tmp_path / "step_junkname")          # unparseable
    assert latest_step(str(tmp_path)) == 4
    restored, step = restore_fit_result(str(tmp_path))
    assert step == 4
    np.testing.assert_array_equal(restored.W, res.W)

    store = FactorStore.from_checkpoint(str(tmp_path))
    assert store.boot_step == 4
    np.testing.assert_array_equal(np.asarray(store.view().W), res.W)
    with pytest.raises(FileNotFoundError):
        FactorStore.from_checkpoint(str(tmp_path / "nope"))


def test_crash_mid_write_leaves_no_committed_step(tmp_path, monkeypatch):
    """Kill the writer mid-shard: the directory must contain only .tmp
    staging — never a COMMITTED marker — so restore sees nothing."""
    def boom(*a, **k):
        raise RuntimeError("killed mid-write")
    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError):
        save_checkpoint(str(tmp_path), 1, _tree())
    assert latest_step(str(tmp_path)) is None
    names = os.listdir(tmp_path)
    assert all(n.endswith(".tmp") for n in names), names
    restored, step = restore_checkpoint(str(tmp_path), _tree())
    assert restored is None and step is None


def test_restore_under_old_p_resumes_under_new_p(tmp_path):
    """Elastic recovery across a worker-count change: the last committed
    checkpoint was taken at p=4, the cluster has since shrunk to p=3,
    and a kill must restore the p=4 state, replay the shrink, and land
    bitwise on the graceful-departure run."""
    from repro import api
    from repro.core.stepsize import PowerSchedule
    problem = api.MCProblem.synthetic(50, 20, 500, k=4, seed=3)
    cfg = api.NomadConfig(k=4, p=4, epochs=1, seed=1, lam=0.01,
                          stepsize=PowerSchedule(alpha=0.02, beta=0.1))
    a = api.StreamingSession(
        problem, cfg, faults=api.FaultPolicy(checkpoint_dir=str(tmp_path),
                                             checkpoint_every=10))
    b = api.StreamingSession(problem, cfg)
    for s in (a, b):
        s.fit()
        s.fit()
    a.checkpoint()                       # manual checkpoint at p=4
    for s in (a, b):
        s.resize(leave=(1,))             # shrink: p=4 -> p=3
        s.fit()
    assert a.config.p == 3
    a.kill(0)                            # restores the p=4 checkpoint
    b.resize(leave=(0,))
    Wa, Ha = a._eng.factors()
    Wb, Hb = b._eng.factors()
    assert a.config.p == 2
    np.testing.assert_array_equal(Wa, Wb)
    np.testing.assert_array_equal(Ha, Hb)


# --------------------------------------------------------------------- #
# Integrity: per-array checksum manifest, quarantine, verified fallback  #
# (DESIGN.md §14)                                                        #
# --------------------------------------------------------------------- #

def test_manifest_written_and_verifies(tmp_path):
    from repro.checkpoint import verify_checkpoint
    save_checkpoint(str(tmp_path), 3, _tree())
    assert os.path.exists(tmp_path / "step_00000003" / "manifest.json")
    assert verify_checkpoint(str(tmp_path), 3)


def test_bitflip_fails_verification_and_quarantines(tmp_path):
    from repro.checkpoint import (committed_steps, latest_verified_step,
                                  verify_checkpoint)
    from repro.runtime.chaos import bitflip_checkpoint
    save_checkpoint(str(tmp_path), 1, _tree(1))
    save_checkpoint(str(tmp_path), 2, _tree(2))
    assert bitflip_checkpoint(str(tmp_path), seed=0) == 2
    assert not verify_checkpoint(str(tmp_path), 2)
    assert verify_checkpoint(str(tmp_path), 1)
    # fallback quarantines the corrupt step and lands on the verified one
    assert latest_verified_step(str(tmp_path)) == 1
    assert os.path.isdir(tmp_path / "step_00000002.corrupt")
    assert committed_steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1


def test_restore_falls_back_past_corruption(tmp_path):
    from repro.checkpoint import CorruptCheckpointError
    from repro.runtime.chaos import bitflip_checkpoint
    t1, t2 = _tree(1), _tree(2)
    save_checkpoint(str(tmp_path), 1, t1)
    save_checkpoint(str(tmp_path), 2, t2)
    bitflip_checkpoint(str(tmp_path), seed=0, step=2)
    # explicitly requesting the corrupted step is a hard error
    with pytest.raises(CorruptCheckpointError):
        restore_checkpoint(str(tmp_path), t1, step=2)
    restored, step = restore_checkpoint(str(tmp_path), t1)
    assert step == 1
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_corrupted_latest_never_boots_fit_result(tmp_path, tiny_mc_problem):
    """The serving-boot contract under corruption: FactorStore boots
    from the newest *verified* step, never from a bitflipped one."""
    from repro import api
    from repro.runtime.chaos import bitflip_checkpoint
    from repro.serve import FactorStore
    pr = tiny_mc_problem
    rows, cols, vals = pr["train"]
    prob = api.MCProblem(rows=rows, cols=cols, vals=vals, m=pr["m"],
                         n=pr["n"])
    cfg = api.NomadConfig(k=4, p=2, epochs=1, seed=0)
    r1 = api.solve(prob, cfg)
    r2 = api.solve(prob, dataclasses.replace(cfg, epochs=2))
    save_fit_result(str(tmp_path), 1, r1)
    save_fit_result(str(tmp_path), 2, r2)
    bitflip_checkpoint(str(tmp_path), seed=3)
    store = FactorStore.from_checkpoint(str(tmp_path))
    assert store.boot_step == 1
    np.testing.assert_array_equal(np.asarray(store.view().W),
                                  np.asarray(r1.W))


def test_all_checkpoints_corrupt_restores_nothing(tmp_path):
    from repro.runtime.chaos import bitflip_checkpoint
    save_checkpoint(str(tmp_path), 1, _tree())
    bitflip_checkpoint(str(tmp_path), seed=0, step=1)
    restored, step = restore_checkpoint(str(tmp_path), _tree())
    assert restored is None and step is None
    assert os.path.isdir(tmp_path / "step_00000001.corrupt")


def test_verify_missing_manifest_is_backwards_compatible(tmp_path):
    """Pre-integrity checkpoints (no manifest.json) still verify: the
    layer must not brick existing checkpoint dirs."""
    from repro.checkpoint import verify_checkpoint
    save_checkpoint(str(tmp_path), 4, _tree())
    os.remove(tmp_path / "step_00000004" / "manifest.json")
    assert verify_checkpoint(str(tmp_path), 4)
    restored, step = restore_checkpoint(str(tmp_path), _tree())
    assert step == 4


def test_config_codec_roundtrips_integrity_types(tmp_path):
    from repro import api
    link = api.DegradedLink(
        events=(api.LinkEvent("drop", t0=1.0, t1=9.0, prob=0.5),),
        dup=0.1, delay_factor=3.0)
    cfg = api.AsyncSimConfig(k=4, p=3, epochs=1.0, seed=0,
                             transport=api.TransportConfig(max_retries=7),
                             link_faults=link)
    prob = api.MCProblem.synthetic(30, 15, 200, k=4, seed=0)
    res = api.solve(prob, cfg)
    save_fit_result(str(tmp_path), 1, res)
    restored, _ = restore_fit_result(str(tmp_path))
    rc = restored.config
    assert rc.transport == cfg.transport
    assert rc.link_faults.events == link.events
    assert rc.link_faults.rates == link.rates
    assert rc.link_faults.delay_factor == link.delay_factor
