"""Network model + locality-aware scheduling (DESIGN.md §12), and the
PR-8 simulator bugfixes.

Covers: hand-computed mesh pricing incl. link contention, the bitwise
flat-fallback guarantee (``topology=None`` == ``UniformTopology(c)``),
the lost-nomadic-item regression (a kill that orphans an in-flight
delivery), the trace final-RMSE guard + record-interval clamp, the
time-weighted throughput denominator, ``OwnershipSchedule.
topology_aware`` (validity, locality preference, makespan win, engine
serializability), and serializability of topology-priced runs under the
full elastic lifecycle.
"""
import numpy as np
import pytest
import strategies
from hypothesis_compat import given, settings, st

from repro.core import objective, serial
from repro.core.async_sim import NomadSimulator, SimConfig, simulate_dsgd
from repro.core.schedule import OwnershipSchedule
from repro.core.stepsize import PowerSchedule
from repro.core.topology import (HierarchicalMesh, UniformTopology,
                                 schedule_makespan)


def _replay(res, rows, cols, vals, W0, H0, sched, lam):
    """Bitwise serial replay of a SimResult's update log (the
    serializability witness — same as test_serializability)."""
    order_idx = sorted(range(len(res.update_log)),
                       key=lambda t: (res.update_log[t][0], t))
    order = np.array([res.update_log[t][1] for t in order_idx])
    cnt = {}
    lrs = np.empty(len(order))
    for t, g in enumerate(order):
        c = cnt.get(g, 0)
        lrs[t] = sched(c)
        cnt[g] = c + 1
    return serial.replay_np(W0, H0, rows, cols, vals, order, lrs, lam)


def _sim_problem(seed, m=40, n=20, nnz=300, k=6):
    rows, cols, vals = strategies.coo_problem(seed, m, n, nnz)
    W0, H0 = objective.init_factors_np(seed, m, n, k)
    return rows, cols, vals, W0, H0


# --------------------------------------------------------------------- #
# Mesh pricing: hand-computed costs and contention                       #
# --------------------------------------------------------------------- #

MESH4 = HierarchicalMesh(p=4, workers_per_node=2, intra_latency=1.0,
                         inter_latency=10.0, intra_cost=2.0,
                         inter_cost=5.0)


def test_mesh_intra_node_cost():
    st_ = MESH4.state()
    # size 4 at intra_cost 2 -> occupies 8, + latency 1
    assert st_.send(0, 1, 4.0, 0.0) == 9.0


def test_mesh_link_contention_serializes():
    st_ = MESH4.state()
    assert st_.send(0, 1, 4.0, 0.0) == 9.0
    # same NIC pair still busy until 8: second transfer queues
    assert st_.send(0, 1, 4.0, 0.0) == 17.0
    # ...but a reverse-direction transfer uses tx1/rx0 — free links
    assert st_.send(1, 0, 4.0, 0.0) == 9.0


def test_mesh_inter_node_cost_and_uplink_contention():
    st_ = MESH4.state()
    # inter: occupy 4 * 5 = 20, + latency 10
    assert st_.send(0, 2, 4.0, 0.0) == 30.0
    # a second transfer out of node 0 (different endpoints) contends on
    # node 0's uplink, busy until 20
    assert st_.send(1, 3, 4.0, 0.0) == 50.0
    # intra-node traffic inside node 1 never touches the uplinks — but
    # worker 3's NIC-rx is busy until 40 from the transfer above
    assert st_.send(2, 3, 4.0, 0.0) == 40.0 + 8.0 + 1.0


def test_mesh_peek_does_not_commit():
    st_ = MESH4.state()
    assert st_.peek(0, 2, 4.0, 0.0) == 30.0
    assert st_.peek(0, 2, 4.0, 0.0) == 30.0   # unchanged: no occupancy
    assert st_.send(0, 2, 4.0, 0.0) == 30.0
    assert st_.peek(0, 2, 4.0, 0.0) == 50.0   # now queued behind


def test_mesh_validation():
    with pytest.raises(ValueError, match="p must be"):
        HierarchicalMesh(p=0)
    with pytest.raises(ValueError, match="node_of has"):
        HierarchicalMesh(p=4, node_of=(0, 0, 1))
    with pytest.raises(ValueError, match="inter_cost"):
        HierarchicalMesh(p=4, inter_cost=-1.0)
    # explicit grouping overrides workers_per_node
    mesh = HierarchicalMesh(p=4, workers_per_node=99,
                            node_of=(0, 1, 0, 1))
    assert mesh.n_nodes == 2 and mesh.same_node(0, 2)


def test_uniform_topology_prices_c_times_size():
    st_ = UniformTopology(c=20.0).state()
    assert st_.send(0, 1, 16, 5.0) == 5.0 + 20.0 * 16
    assert st_.peek(3, 2, 16, 5.0) == 5.0 + 20.0 * 16  # no contention


# --------------------------------------------------------------------- #
# Flat fallback: topology=None == UniformTopology(c), bitwise            #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("mode", ["nomad", "dsgd", "dsgd++"])
def test_flat_topology_is_bitwise_fallback(mode):
    rows, cols, vals, W0, H0 = _sim_problem(3)
    test = (rows[:50], cols[:50], vals[:50])
    base = dict(p=4, k=6, lam=0.01,
                schedule=PowerSchedule(alpha=0.02, beta=0.1),
                epochs=2.0, seed=3)

    def run(cfg):
        if mode == "nomad":
            return NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0,
                                  test=test).run()
        return simulate_dsgd(cfg, 40, 20, rows, cols, vals, W0, H0,
                             test=test, overlap=mode == "dsgd++")

    r0 = run(SimConfig(**base))
    r1 = run(SimConfig(**base, topology=UniformTopology(c=20.0)))
    assert np.array_equal(r0.W, r1.W) and np.array_equal(r0.H, r1.H)
    assert r0.sim_time == r1.sim_time
    assert r0.update_log == r1.update_log
    assert r0.trace == r1.trace
    assert r0.throughput == r1.throughput


# --------------------------------------------------------------------- #
# Bugfix: in-flight deliveries to a dead worker are re-routed, not lost  #
# --------------------------------------------------------------------- #

def test_kill_orphaned_delivery_item_keeps_circulating():
    """Regression for the lost-nomadic-item bug: with p=2 and worker 1
    killed mid-run, any ``"arrive"`` event already in the heap and
    addressed to worker 1 was silently dropped (`if not alive[q]:
    continue`), permanently removing that item from circulation and
    starving its ``H[j]``.  On this seed the pre-fix simulator loses
    items {1, 7} (verified against the pre-fix code); post-fix every
    item must keep visiting live workers after the kill, and the run
    stays bitwise-serializable."""
    m, n, nnz = 20, 10, 200
    rows, cols, vals = strategies.coo_problem(7, m, n, nnz)
    W0, H0 = objective.init_factors_np(7, m, n, 4)
    sched = PowerSchedule(alpha=0.02, beta=0.1)
    t_kill = 120.0
    # c=50 keeps many deliveries in flight at any instant, so the kill
    # reliably orphans at least one heap-resident arrive event
    cfg = SimConfig(p=2, k=4, lam=0.01, schedule=sched, epochs=4.0,
                    seed=7, c=50.0, failures=((t_kill, 1),))
    res = NomadSimulator(cfg, m, n, rows, cols, vals, W0, H0).run()
    post_kill = {j for t, q, j in res.visit_log if t >= t_kill}
    assert post_kill == set(range(n)), (
        f"items {sorted(set(range(n)) - post_kill)} left circulation "
        "after the kill")
    # deliveries bounce only to live workers
    for t, q, _ in res.visit_log:
        if t >= t_kill:
            assert q == 0
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, sched, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


@settings(max_examples=6, deadline=None)
@given(p=st.integers(2, 5), seed=st.integers(0, 10_000))
def test_no_item_lost_under_kill_property(p, seed):
    """Every item stays in circulation across a kill, for any worker
    count: after the failure each of the n items is still visited."""
    m, n, nnz = 30, 12, 250
    rows, cols, vals = strategies.coo_problem(seed, m, n, nnz)
    W0, H0 = objective.init_factors_np(seed, m, n, 4)
    sched = PowerSchedule(alpha=0.02, beta=0.1)
    cfg = SimConfig(p=p, k=4, lam=0.01, schedule=sched, epochs=5.0,
                    seed=seed, c=40.0, failures=((100.0, p - 1),))
    res = NomadSimulator(cfg, m, n, rows, cols, vals, W0, H0).run()
    post_kill = {j for t, q, j in res.visit_log if t >= 100.0}
    assert post_kill == set(range(n))
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, sched, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


# --------------------------------------------------------------------- #
# Bugfix: trace final-RMSE guard + record-interval clamp                 #
# --------------------------------------------------------------------- #

def test_trace_always_ends_at_final_state():
    """record_every larger than the whole run used to leave trace empty
    (or stale): the final entry must reflect the final factors, exactly
    like simulate_dsgd's guard."""
    rows, cols, vals, W0, H0 = _sim_problem(5)
    test = (rows[:50], cols[:50], vals[:50])
    cfg = SimConfig(p=3, k=6, lam=0.01,
                    schedule=PowerSchedule(alpha=0.02, beta=0.1),
                    epochs=1.0, seed=5, record_every=100.0)
    res = NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0,
                         test=test).run()
    assert len(res.trace) == 1
    t, n_up, r = res.trace[-1]
    assert n_up == res.n_updates and t == res.sim_time
    assert r == objective.rmse_np(res.W, res.H, *test)


def test_record_interval_clamped_to_one_update():
    """record_every * nnz < 1 used to floor the interval to 0, so
    ``record_at`` never advanced and every finish event appended an
    entry (including duplicates at the same update count).  Clamped to
    one update: counts are strictly increasing and bounded by
    n_updates."""
    rows, cols, vals, W0, H0 = _sim_problem(6)
    test = (rows[:50], cols[:50], vals[:50])
    cfg = SimConfig(p=3, k=6, lam=0.01,
                    schedule=PowerSchedule(alpha=0.02, beta=0.1),
                    epochs=0.5, seed=6, record_every=1e-9)
    res = NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0,
                         test=test).run()
    counts = [n_up for _, n_up, _ in res.trace]
    assert counts, "no trace recorded"
    assert all(b > a for a, b in zip(counts, counts[1:])), \
        "duplicate trace entries: record interval not clamped"
    assert len(res.trace) <= res.n_updates + 1
    assert counts[-1] == res.n_updates


# --------------------------------------------------------------------- #
# Bugfix: time-weighted throughput denominator                           #
# --------------------------------------------------------------------- #

def test_throughput_uses_time_weighted_alive_workers():
    """Hand-computed two-phase scenario: p=3 until the kill at t=120,
    then 2 workers for the rest.  The denominator must be the
    time-weighted average, not the final head-count."""
    rows, cols, vals, W0, H0 = _sim_problem(9)
    t_kill = 120.0
    cfg = SimConfig(p=3, k=6, lam=0.01,
                    schedule=PowerSchedule(alpha=0.02, beta=0.1),
                    epochs=3.0, seed=9, failures=((t_kill, 0),))
    res = NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0).run()
    T = res.sim_time
    assert T > t_kill
    avg_alive = (3.0 * t_kill + 2.0 * (T - t_kill)) / T
    assert np.isclose(res.throughput,
                      res.n_updates / (T * avg_alive), rtol=1e-12)
    # the old formula (final head-count) is measurably different
    assert not np.isclose(res.throughput, res.n_updates / (T * 2.0),
                          rtol=1e-3)


def test_throughput_without_lifecycle_is_bitwise_unchanged():
    """No failures/rejoins: the historical constant-denominator formula
    must be reproduced exactly (bitwise fallback guarantee)."""
    rows, cols, vals, W0, H0 = _sim_problem(4)
    cfg = SimConfig(p=4, k=6, lam=0.01,
                    schedule=PowerSchedule(alpha=0.02, beta=0.1),
                    epochs=1.0, seed=4)
    res = NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0).run()
    assert res.throughput == res.n_updates / (max(res.sim_time, 1e-12)
                                              * 4)


def test_throughput_counts_rejoined_worker_time():
    """Kill at 100, rejoin at 400: the average must dip between the two
    and recover after — i.e. depend on both lifecycle boundaries."""
    rows, cols, vals, W0, H0 = _sim_problem(12)
    cfg = SimConfig(p=3, k=6, lam=0.01,
                    schedule=PowerSchedule(alpha=0.02, beta=0.1),
                    epochs=3.0, seed=12, failures=((100.0, 0),),
                    rejoins=((400.0, 0),))
    res = NomadSimulator(cfg, 40, 20, rows, cols, vals, W0, H0).run()
    T = res.sim_time
    assert T > 400.0
    avg = (3.0 * 100.0 + 2.0 * 300.0 + 3.0 * (T - 400.0)) / T
    assert np.isclose(res.throughput, res.n_updates / (T * avg),
                      rtol=1e-12)


# --------------------------------------------------------------------- #
# Topology-aware schedules                                               #
# --------------------------------------------------------------------- #

def _inter_node_moves(sched, mesh):
    """Count block transfers that cross a node boundary over the whole
    epoch (entry from home, per-step moves, exit back home)."""
    p = sched.p
    moves = 0
    prev = np.arange(p)                        # prev[q] = block held
    tables = list(sched.table) + [np.arange(p)]
    for row in tables:
        inv = np.empty(p, dtype=int)
        inv[prev] = np.arange(p)
        for q in range(p):
            src = int(inv[int(row[q])])
            if src != q and not mesh.same_node(src, q):
                moves += 1
        prev = np.asarray(row)
    return moves


MESH8 = HierarchicalMesh(p=8, workers_per_node=4, intra_cost=1.0,
                         inter_cost=30.0, inter_latency=10.0)


def test_topology_aware_is_valid_and_deterministic():
    loads = np.abs(np.random.default_rng(0).normal(size=(8, 8))) * 40
    a = OwnershipSchedule.topology_aware(8, seed=1, loads=loads,
                                         net=MESH8, block_size=20.0)
    b = OwnershipSchedule.topology_aware(8, seed=1, loads=loads,
                                         net=MESH8, block_size=20.0)
    assert a == b and a.name == "topology"
    # constructor validation already enforces the generalized-diagonal +
    # coverage invariants; spot-check the epoch shape
    assert a.n_steps >= 8
    with pytest.raises(ValueError, match="loads must have shape"):
        OwnershipSchedule.topology_aware(8, loads=np.ones((3, 3)),
                                         net=MESH8)


def test_topology_aware_prefers_intra_node_hops():
    loads = np.full((8, 8), 30.0)
    topo = OwnershipSchedule.topology_aware(8, seed=0, loads=loads,
                                            net=MESH8, block_size=20.0)
    bal = OwnershipSchedule.balanced(8, seed=0, loads=loads)
    assert _inter_node_moves(topo, MESH8) < _inter_node_moves(bal, MESH8)


def test_topology_aware_beats_balanced_on_makespan():
    """The acceptance property: on a 2-level mesh, the topology-aware
    schedule's simulated wall-clock beats topology-blind balanced —
    priced by the same model, per-step barrier semantics."""
    rng = np.random.default_rng(2)
    loads = rng.integers(10, 60, (8, 8)).astype(float)
    topo = OwnershipSchedule.topology_aware(8, seed=0, loads=loads,
                                            net=MESH8, block_size=20.0)
    bal = OwnershipSchedule.balanced(8, seed=0, loads=loads)
    mk_t = schedule_makespan(topo, loads, MESH8, block_size=20.0)
    mk_b = schedule_makespan(bal, loads, MESH8, block_size=20.0)
    assert mk_t < mk_b, (mk_t, mk_b)


def test_makespan_without_net_is_padded_compute():
    """net=None prices transfers at zero: the makespan is the sum of
    per-step maxima of the active cell costs."""
    sched = OwnershipSchedule.ring(3)
    loads = np.arange(9, dtype=float).reshape(3, 3)
    want = sum(max(loads[q, sched.table[s, q]] for q in range(3))
               for s in range(3))
    assert schedule_makespan(sched, loads) == want
    with pytest.raises(ValueError, match="loads must have shape"):
        schedule_makespan(sched, np.ones((2, 2)))


@pytest.mark.parametrize("impl", ["xla", "wave"])
def test_engine_executes_topology_aware_schedule(impl):
    """topology_aware compiles to a schedule both executors run like any
    other: engine output over two epochs == serial replay of
    schedule_order() (the serializability witness)."""
    import jax.numpy as jnp
    from repro.core import nomad, partition as P
    p, m, n, k, nnz = 4, 40, 20, 6, 300
    rows, cols, vals = strategies.coo_problem(13, m, n, nnz)
    mesh = HierarchicalMesh(p=4, workers_per_node=2, intra_cost=1.0,
                            inter_cost=25.0)
    sched = OwnershipSchedule.topology_aware(p, seed=13, net=mesh,
                                             block_size=10.0)
    br = P.pack(rows, cols, vals, m, n, p, schedule=sched)
    order = br.schedule_order()
    assert np.array_equal(np.sort(order), np.arange(nnz))
    W0, H0 = objective.init_factors_np(13, m, n, k)
    W0, H0 = W0.astype(np.float32), H0.astype(np.float32)
    lr = PowerSchedule(alpha=0.02, beta=0.1)
    eng = nomad.NomadRingEngine(br=br, k=k, lam=0.01, stepsize=lr,
                                impl=impl)
    eng.init_factors(W0, H0)
    Wr, Hr = jnp.asarray(W0), jnp.asarray(H0)
    for e in range(2):
        eng.run_epoch()
        Wr, Hr = serial.replay_jax(Wr, Hr, rows, cols, vals, order,
                                   lr(e), 0.01)
    W1, H1 = eng.factors()
    np.testing.assert_allclose(np.asarray(Wr), W1, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(Hr), H1, rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------- #
# Serializability of topology-priced runs; sim -> engine compilation     #
# --------------------------------------------------------------------- #

@settings(max_examples=8, deadline=None)
@given(**strategies.MESH_SIM)
def test_serializable_on_mesh_with_lifecycle(p, seed, straggle, churn):
    """The §3.2 headline property survives the real network: under a
    non-uniform 2-level mesh (contended links, placement-dependent
    latency), with stragglers and the full failure + rejoin lifecycle,
    the execution stays bitwise-serializable."""
    rng = np.random.default_rng(seed)
    m, n, nnz = 30, 15, 250
    rows, cols, vals = strategies.coo_problem(seed, m, n, nnz)
    W0, H0 = objective.init_factors_np(seed, m, n, 4)
    sched = PowerSchedule(alpha=0.02, beta=0.1)
    mesh = strategies.mesh_topology(seed, p)
    speed = (1.0 + rng.random(p) * 3) if straggle else None
    failures = ((60.0, p - 1),) if churn and p > 1 else ()
    rejoins = ((500.0, p - 1),) if churn and p > 1 else ()
    cfg = SimConfig(p=p, k=4, lam=0.01, schedule=sched, epochs=2.0,
                    seed=seed, speed=speed, topology=mesh,
                    failures=failures, rejoins=rejoins)
    res = NomadSimulator(cfg, m, n, rows, cols, vals, W0, H0).run()
    assert res.n_updates > 0
    Wr, Hr = _replay(res, rows, cols, vals, W0, H0, sched, 0.01)
    assert np.array_equal(Wr, res.W)
    assert np.array_equal(Hr, res.H)


def test_from_sim_log_compiles_topology_priced_run():
    """A topology-priced visit log (mesh latencies, contention, plus a
    failure) compiles into a complete engine-executable schedule: every
    rating applied exactly once under schedule_order()."""
    from repro import api
    m, n, nnz = 30, 15, 250
    rows, cols, vals = strategies.coo_problem(21, m, n, nnz)
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=m, n=n)
    mesh = HierarchicalMesh(p=4, workers_per_node=2, intra_cost=1.0,
                            inter_cost=15.0, inter_latency=5.0)
    sim = api.solve(problem, api.AsyncSimConfig(
        k=4, p=4, epochs=1.5, emit_schedule=True, topology=mesh,
        failures=((40.0, 3),)))
    sched = sim.extras["schedule"]
    assert isinstance(sched, OwnershipSchedule) and sched.p == 4
    br = problem.packed(4, schedule=sched)
    order = br.schedule_order()
    assert np.array_equal(np.sort(order), np.arange(nnz))
    # and the engine actually runs it
    res = api.solve(problem, api.NomadConfig(k=4, p=4, epochs=1,
                                             schedule=sched))
    assert res.W.shape == (m, 4)


# --------------------------------------------------------------------- #
# API plumbing                                                           #
# --------------------------------------------------------------------- #

def test_async_sim_config_validates_topology():
    from repro import api
    with pytest.raises(TypeError, match="NetworkModel"):
        api.AsyncSimConfig(p=4, topology="mesh")
    with pytest.raises(ValueError, match="p=8"):
        api.AsyncSimConfig(p=4, topology=HierarchicalMesh(p=8))
    cfg = api.AsyncSimConfig(p=4, topology=HierarchicalMesh(p=4))
    assert cfg.to_sim_config().topology == cfg.topology
    # UniformTopology has no worker count to cross-check
    api.AsyncSimConfig(p=4, topology=UniformTopology(c=5.0))


def test_solve_with_mesh_topology_slows_virtual_time():
    """End-to-end through the front door: the same problem under a slow
    mesh must report a larger virtual time than the flat model while
    still completing the requested epoch of work."""
    from repro import api
    rows, cols, vals = strategies.coo_problem(17, 30, 15, 250)
    problem = api.MCProblem(rows=rows, cols=cols, vals=vals, m=30, n=15)
    flat = api.solve(problem, api.AsyncSimConfig(k=4, p=4, epochs=1.0))
    mesh = api.solve(problem, api.AsyncSimConfig(
        k=4, p=4, epochs=1.0,
        topology=HierarchicalMesh(p=4, workers_per_node=2,
                                  intra_cost=20.0, inter_cost=200.0)))
    assert mesh.virtual_time > flat.virtual_time
    assert mesh.extras["n_updates"] >= 250
    assert flat.extras["n_updates"] >= 250
